"""AST lint pass for the serving stack's concurrency invariants.

Four rules (policy tables in :mod:`repro.analysis.rules`):

PG001
    No jax dispatch (``jax.*``/``jnp.*`` calls), plan builds
    (``build_plan``/``plan_for``), or blocking calls (``time.sleep``,
    ``thread.join``, ``future.result``, ``concurrent.futures.wait``)
    inside a ``with <lock>:`` body. A multi-millisecond XLA call under a
    lock stalls every other thread; ``Condition.wait`` is exempt because
    it releases the lock while parked.

PG002
    An attribute assignment annotated ``# guarded-by: <lock>`` makes every
    later touch of that attribute (module-wide, by attribute name — locks
    are matched by NAME, the repo's one-lock-per-name convention) illegal
    outside a ``with`` on that lock. ``__init__`` bodies are exempt
    (construction precedes sharing); helpers whose contract is
    "caller holds the lock" carry ``# holds: <lock>``.

PG003
    Syntactically nested lock acquisitions must respect the declared
    hierarchy (``rules.STATIC_LOCK_ORDER``, outer->inner by ascending
    rank). Cross-function nesting is the runtime sanitizer's job.

PG004
    Jitted forwards (functions named ``forward``/``_pure``, arguments of
    ``jax.jit``) and Pallas kernel bodies (first argument of
    ``pl.pallas_call``, through ``functools.partial``) run at TRACE time:
    no ``time.*``/``random.*`` calls, no ``print``/``open``, no lock
    acquisition, no mutation of nonlocal state. Donation safety rides
    along: an argument donated via ``donate_argnums`` must not be read
    after the jitted call without an intervening rebind.

Findings are suppressed by ``# pegasus-lint: disable=PGxxx <reason>``
(same line or the line above) or ``disable-block=`` on a compound
statement's header; a suppression without a reason is itself a finding
(PG000).
"""

from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass
from pathlib import Path

from . import rules as R

__all__ = ["Finding", "lint_source", "lint_file", "lint_paths", "main"]


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _final_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _with_locks(node: ast.With) -> list[str]:
    """Canonical lock names acquired by a with statement's items."""
    out = []
    for item in node.items:
        name = _final_name(item.context_expr)
        if name is None and isinstance(item.context_expr, ast.Call):
            # `with lock:` not `with open(...)` — but `with self._lock:`
            # is a bare attribute; a Call context (e.g. `with cond_for(x):`)
            # is not a lock by this convention
            continue
        if name is None:
            continue
        lock = R.canonical_lock(name)
        if lock is not None:
            out.append(lock)
    return out


class _Linter:
    def __init__(self, src: str, path: str, *,
                 lock_ranks: dict[str, int] | None = None):
        self.src = src
        self.path = path
        self.stem = Path(path).stem
        self.ranks = (R.static_ranks_for_module(self.stem)
                      if lock_ranks is None else dict(lock_ranks))
        self.findings: list[Finding] = []
        self.comments = self._collect_comments(src)
        self.tree = ast.parse(src)
        self.assign_attr_at = self._collect_attr_assign_lines(self.tree)
        self.guarded = self._collect_guarded()
        self.holds = self._collect_holds(self.tree)
        self.pure_defs = self._collect_pure_defs(self.tree)
        self.donated = self._collect_donated_bindings(self.tree)

    # -- plumbing -----------------------------------------------------------

    def _emit(self, rule: str, line: int, message: str) -> None:
        self.findings.append(Finding(self.path, line, rule, message))

    @staticmethod
    def _collect_comments(src: str) -> dict[int, str]:
        out: dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(io.StringIO(src).readline):
                if tok.type == tokenize.COMMENT:
                    out[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover - defensive
            pass
        return out

    @staticmethod
    def _collect_attr_assign_lines(tree: ast.Module) -> dict[int, str]:
        """line -> attribute name, for `self.x = ...` style assignments."""
        out: dict[int, str] = {}
        for node in ast.walk(tree):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute):
                    out.setdefault(t.lineno, t.attr)
        return out

    def _collect_guarded(self) -> dict[str, str]:
        """attribute name -> required lock name, from guarded-by comments
        (on the assignment line, or on a standalone line directly above)."""
        out: dict[str, str] = {}
        for line, comment in self.comments.items():
            m = R.GUARDED_BY_RE.search(comment)
            if not m:
                continue
            attr = (self.assign_attr_at.get(line)
                    or self.assign_attr_at.get(line + 1))
            if attr is None:
                self._emit("PG000", line,
                           "guarded-by comment is not attached to an "
                           "attribute assignment")
                continue
            out[attr] = m.group(1)
        return out

    def _collect_holds(self, tree: ast.Module) -> dict[ast.AST, list[str]]:
        """FunctionDef -> lock names the caller is contracted to hold."""
        out: dict[ast.AST, list[str]] = {}
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            locks = []
            for line in (node.lineno, node.lineno - 1):
                comment = self.comments.get(line)
                if comment:
                    m = R.HOLDS_RE.search(comment)
                    if m:
                        lock = R.canonical_lock(m.group(1)) or m.group(1)
                        locks.append(lock)
            if locks:
                out[node] = locks
        return out

    # -- PG004 prep ---------------------------------------------------------

    def _collect_pure_defs(self, tree: ast.Module) -> list[ast.FunctionDef]:
        defs_by_name: dict[str, ast.FunctionDef] = {}
        pure: dict[int, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef):
                defs_by_name.setdefault(node.name, node)
                # EVERY def named by convention is traced — the structural
                # forwards are all local functions named `forward`
                if node.name in R.PURE_FUNC_NAMES:
                    pure[id(node)] = node
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            dotted = _dotted(node.func) or ""
            target = None
            if dotted == "jax.jit" or dotted.endswith(".pallas_call") \
                    or dotted == "pallas_call":
                target = node.args[0]
            if target is None:
                continue
            # unwrap functools.partial(kernel_fn, ...)
            if isinstance(target, ast.Call):
                inner = _dotted(target.func) or ""
                if inner in ("functools.partial", "partial") and target.args:
                    target = target.args[0]
            if isinstance(target, ast.Name) and target.id in defs_by_name:
                fn = defs_by_name[target.id]
                pure[id(fn)] = fn
        return list(pure.values())

    def _collect_donated_bindings(self, tree: ast.Module) -> dict[str, list]:
        """dotted bound path (e.g. "self._jit") -> donated positional
        indices, from `X = jax.jit(fn, donate_argnums=(...))`."""
        out: dict[str, list[int]] = {}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not isinstance(call, ast.Call):
                continue
            if (_dotted(call.func) or "") != "jax.jit":
                continue
            idxs: list[int] = []
            for kw in call.keywords:
                if kw.arg != "donate_argnums":
                    continue
                vals = (kw.value.elts
                        if isinstance(kw.value, ast.Tuple) else [kw.value])
                for v in vals:
                    if isinstance(v, ast.Constant) and isinstance(v.value,
                                                                  int):
                        idxs.append(v.value)
            if not idxs:
                continue
            for t in node.targets:
                path = _dotted(t)
                if path:
                    out[path] = idxs
        return out

    # -- main walk (PG001 + PG002 + PG003) ----------------------------------

    def run(self) -> list[Finding]:
        self._walk_body(self.tree.body, held=(), fname=None)
        for fn in self.pure_defs:
            self._check_pure(fn)
        self._check_donation(self.tree)
        return self.findings

    def _walk_body(self, stmts, held: tuple, fname: str | None) -> None:
        for node in stmts:
            self._walk_stmt(node, held, fname)

    def _walk_stmt(self, node: ast.AST, held: tuple,
                   fname: str | None) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            base = tuple(self.holds.get(node, ()))
            self._walk_body(node.body, held=base, fname=node.name)
            return
        if isinstance(node, ast.ClassDef):
            self._walk_body(node.body, held=(), fname=None)
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            locks = _with_locks(node)
            for lock in locks:
                self._check_pg003(lock, held, node.lineno)
            inner = held + tuple(lk for lk in locks if lk not in held)
            for item in node.items:
                self._check_exprs(item.context_expr, held, fname)
            self._walk_body(node.body, held=inner, fname=fname)
            return
        # compound statements: recurse into child statement lists, check
        # the expression parts at the current held set
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(node, field, None)
            if sub:
                self._walk_body(sub, held, fname)
        for h in getattr(node, "handlers", []) or []:
            self._walk_body(h.body, held, fname)
        self._check_exprs(node, held, fname, skip_stmts=True)

    def _check_exprs(self, node: ast.AST, held: tuple, fname: str | None,
                     *, skip_stmts: bool = False) -> None:
        """PG001 + PG002 over the expression parts of one statement."""
        for child in self._expr_walk(node, skip_stmts=skip_stmts):
            if isinstance(child, ast.Call) and held:
                self._check_pg001(child, held)
            if isinstance(child, ast.Attribute):
                self._check_pg002(child, held, fname)

    def _expr_walk(self, node: ast.AST, *, skip_stmts: bool):
        """Walk expressions, skipping nested statement bodies (already
        visited with their own held sets) and nested function defs.
        Lambdas ARE descended into: they execute where they appear in
        this codebase's hot paths (min(key=...), sort(key=...))."""
        stack = [node]
        first = True
        while stack:
            n = stack.pop()
            if not first and isinstance(
                    n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                continue
            if not first and skip_stmts and isinstance(n, ast.stmt):
                continue  # nested statements are visited with their own
                # held sets by _walk_body; only this statement's own
                # expression parts belong to this check
            first = False
            yield n
            stack.extend(ast.iter_child_nodes(n))

    def _check_pg001(self, call: ast.Call, held: tuple) -> None:
        dotted = _dotted(call.func)
        root = dotted.split(".", 1)[0] if dotted else None
        lockset = ", ".join(sorted(set(held)))
        if root in R.JAX_ROOTS:
            self._emit("PG001", call.lineno,
                       f"jax dispatch `{dotted}` inside `with {lockset}:` "
                       "(device/XLA work stalls every waiter)")
            return
        if isinstance(call.func, ast.Name) and call.func.id in R.PLAN_CALLS:
            self._emit("PG001", call.lineno,
                       f"plan build `{call.func.id}` inside `with "
                       f"{lockset}:` (compiles run OUTSIDE locks)")
            return
        if dotted in R.BLOCKING_DOTTED or (
                dotted and dotted.endswith("futures.wait")):
            self._emit("PG001", call.lineno,
                       f"blocking call `{dotted}` inside `with {lockset}:`")
            return
        final = _final_name(call.func)
        if final in R.BLOCKING_FINAL_ATTRS:
            recv = (call.func.value
                    if isinstance(call.func, ast.Attribute) else None)
            if isinstance(recv, ast.Constant) and isinstance(recv.value, str):
                return  # ", ".join(...) — string formatting, not a thread
            self._emit("PG001", call.lineno,
                       f"blocking `.{final}()` inside `with {lockset}:`")
            return
        # receiver-sensitive: queue.Queue.get/put and Event.wait block too,
        # but only on queue/event-like receivers (dict.get and the
        # lock-releasing Condition.wait stay exempt) — matched by the
        # receiver's name, the lint's usual convention contract
        if final is not None and isinstance(call.func, ast.Attribute):
            recv_name = _final_name(call.func.value)
            if R.blocking_receiver(final, recv_name, len(call.args)):
                self._emit(
                    "PG001", call.lineno,
                    f"blocking `{recv_name}.{final}()` (queue/event wait) "
                    f"inside `with {lockset}:`")

    def _check_pg002(self, attr: ast.Attribute, held: tuple,
                     fname: str | None) -> None:
        required = self.guarded.get(attr.attr)
        if required is None:
            return
        if fname is None or fname in ("__init__", "__new__"):
            return  # module/class level defaults and construction
        if R.canonical_lock(required) in held or required in held:
            return
        self._emit("PG002", attr.lineno,
                   f"`{_dotted(attr) or attr.attr}` is guarded-by "
                   f"`{required}` but no `with {required}:` (or "
                   f"`# holds: {required}` contract) is in effect here")

    def _check_pg003(self, lock: str, held: tuple, line: int) -> None:
        my_rank = self.ranks.get(lock)
        for h in held:
            if h == lock:
                continue
            h_rank = self.ranks.get(h)
            if my_rank is not None and h_rank is not None \
                    and h_rank > my_rank:
                self._emit("PG003", line,
                           f"`{lock}` (rank {my_rank}) acquired while "
                           f"holding `{h}` (rank {h_rank}); declared "
                           "hierarchy is outer->inner by ascending rank")

    # -- PG004 --------------------------------------------------------------

    def _check_pure(self, fn: ast.FunctionDef) -> None:
        locals_: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                a = node.args
                for arg in (a.posonlyargs + a.args + a.kwonlyargs
                            + ([a.vararg] if a.vararg else [])
                            + ([a.kwarg] if a.kwarg else [])):
                    locals_.add(arg.arg)
            elif isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)):
                locals_.add(node.id)
        where = f"jitted/traced body `{fn.name}`"
        for node in ast.walk(fn):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for lock in _with_locks(node):
                    self._emit("PG004", node.lineno,
                               f"lock `{lock}` acquired inside {where} "
                               "(runs at trace time, holds the lock for "
                               "the whole trace)")
            elif isinstance(node, ast.Call):
                self._check_pure_call(node, locals_, where)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    if not isinstance(t, ast.Attribute):
                        continue
                    root = (_dotted(t) or "").split(".", 1)[0]
                    if root and root not in locals_:
                        self._emit("PG004", t.lineno,
                                   f"mutation of nonlocal `{_dotted(t)}` "
                                   f"inside {where} (side effect fires at "
                                   "trace time only)")

    def _check_pure_call(self, call: ast.Call, locals_: set,
                         where: str) -> None:
        dotted = _dotted(call.func)
        if dotted:
            parts = tuple(dotted.split("."))
            if parts[0] in R.IMPURE_ROOTS and parts[0] not in locals_:
                self._emit("PG004", call.lineno,
                           f"impure call `{dotted}` inside {where}")
                return
            for prefix in R.IMPURE_DOTTED_PREFIXES:
                if parts[:len(prefix)] == prefix:
                    self._emit("PG004", call.lineno,
                               f"nondeterministic call `{dotted}` inside "
                               f"{where}")
                    return
            if (len(parts) > 1 and parts[-1] in R.MUTATOR_METHODS
                    and parts[0] not in locals_
                    and parts[0] not in R.SAFE_MUTATOR_ROOTS):
                self._emit("PG004", call.lineno,
                           f"mutating call `{dotted}` on nonlocal state "
                           f"inside {where}")
                return
        if isinstance(call.func, ast.Name) \
                and call.func.id in R.IMPURE_BUILTINS \
                and call.func.id not in locals_:
            self._emit("PG004", call.lineno,
                       f"side-effecting builtin `{call.func.id}` inside "
                       f"{where}")

    def _check_donation(self, tree: ast.Module) -> None:
        if not self.donated:
            return
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                path = _dotted(node.func)
                idxs = self.donated.get(path or "")
                if not idxs:
                    continue
                for i in idxs:
                    if i < len(node.args):
                        arg_path = _dotted(node.args[i])
                        if arg_path:
                            self._check_donated_use(fn, node.lineno,
                                                    arg_path, path)

    def _check_donated_use(self, fn: ast.AST, call_line: int,
                           arg_path: str, jit_path: str) -> None:
        loads, stores = [], []
        for node in ast.walk(fn):
            path = _dotted(node)
            if path != arg_path:
                continue
            ctx = getattr(node, "ctx", None)
            if isinstance(ctx, ast.Store):
                stores.append(node.lineno)
            elif isinstance(ctx, ast.Load):
                loads.append(node.lineno)
        for load in sorted(loads):
            if load <= call_line:
                continue
            # a rebind on the call line itself (y, x = jit(..., x)) or any
            # line up to the load makes the read safe
            if any(call_line <= s <= load for s in stores):
                continue
            self._emit("PG004", load,
                       f"donated buffer `{arg_path}` read after the jitted "
                       f"call `{jit_path}(...)` on line {call_line} (its "
                       "storage may already be reused by XLA)")
            break  # one finding per call site is enough

    # -- suppressions -------------------------------------------------------

    def apply_suppressions(self, findings: list[Finding]) -> list[Finding]:
        line_sup: dict[int, set] = {}
        block_spans: list[tuple[int, int, set]] = []
        meta: list[Finding] = []
        header_lines = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.stmt) and hasattr(node, "end_lineno"):
                header_lines.setdefault(node.lineno, node.end_lineno)
        for line, comment in self.comments.items():
            m = R.SUPPRESS_RE.search(comment)
            if not m:
                continue
            kind, ids, reason = m.group(1), m.group(2), m.group(3).strip()
            ruleset = {r for r in ids.split(",") if r}
            if not ruleset or not all(r in R.RULES for r in ruleset) \
                    or not reason:
                meta.append(Finding(
                    self.path, line, "PG000",
                    "suppression needs valid rule IDs and a written "
                    f"justification: {comment.strip()!r}"))
            if not ruleset:
                continue
            if kind == "disable-block":
                # inline on the header, or standalone directly above it
                end = header_lines.get(line) or header_lines.get(
                    line + 1, line + 1)
                block_spans.append((line, end, ruleset))
            else:
                line_sup.setdefault(line, set()).update(ruleset)

        def suppressed(f: Finding) -> bool:
            for at in (f.line, f.line - 1):
                if f.rule in line_sup.get(at, ()):
                    return True
            return any(start <= f.line <= end and f.rule in ruleset
                       for start, end, ruleset in block_spans)

        kept = [f for f in findings if not suppressed(f)]
        kept.extend(meta)
        return kept


def lint_source(src: str, path: str = "<string>", *,
                lock_ranks: dict[str, int] | None = None) -> list[Finding]:
    """Lint one module's source; returns unsuppressed findings sorted by
    line. ``lock_ranks`` overrides the module's PG003 rank table (fixture
    tests declare their own hierarchies)."""
    try:
        linter = _Linter(src, path, lock_ranks=lock_ranks)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "PG000",
                        f"file does not parse: {e.msg}")]
    findings = linter.run()
    findings = linter.apply_suppressions(findings)
    return sorted(findings, key=lambda f: (f.line, f.rule, f.message))


def lint_file(path, *, lock_ranks: dict[str, int] | None = None
              ) -> list[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), str(p), lock_ranks=lock_ranks)


def lint_paths(paths) -> list[Finding]:
    """Lint every ``*.py`` under the given files/directories."""
    files: list[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        else:
            files.append(p)
    out: list[Finding] = []
    for f in files:
        out.extend(lint_file(f))
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Concurrency invariant lint for the Pegasus serving "
                    "stack (PG001-PG004; see repro/analysis/rules.py)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in sorted({**R.RULES, **R.PGA_RULES}.items()):
            print(f"{rule}: {desc}")
        return 0
    findings = lint_paths(args.paths or ["src"])
    for f in findings:
        print(f)
    n = len(findings)
    print(f"pegasus-lint: {n} unsuppressed finding{'s' if n != 1 else ''}")
    return 1 if findings else 0
