"""``python -m repro.analysis [paths...]`` — the AST lint (PG0xx), or
``python -m repro.analysis plan [--json ...]`` — the plan auditor (PGA1xx).
Both exit nonzero on unsuppressed findings."""

import sys

if len(sys.argv) > 1 and sys.argv[1] == "plan":
    from .planaudit import main as plan_main

    sys.exit(plan_main(sys.argv[2:]))

from .lint import main

sys.exit(main())
