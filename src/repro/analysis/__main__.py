"""``python -m repro.analysis [paths...]`` — exit nonzero on findings."""

import sys

from .lint import main

sys.exit(main())
