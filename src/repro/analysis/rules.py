"""Rule registry + repo-specific configuration for the concurrency lint.

This module is pure data: rule IDs, the comment grammar, the declared lock
hierarchy, and the call-classification sets the AST passes in
:mod:`repro.analysis.lint` consult. Keeping it separate means the policy a
finding enforces is reviewable without reading the walker code — and the
runtime sanitizer (:mod:`repro.analysis.sanitizer`) shares the SAME
hierarchy table, so the static and dynamic checks can never disagree about
which nesting order is legal.

Comment grammar (all parsed by regex out of the token stream):

``# guarded-by: <lock>``
    On (or directly above) a ``self.<attr> = ...`` assignment: every later
    touch of ``<attr>`` anywhere in the module must happen under a ``with``
    on a lock whose attribute name matches ``<lock>`` (PG002).

``# holds: <lock>``
    On (or directly above) a ``def``: the function's contract is that the
    CALLER already holds ``<lock>`` — its body is checked as if the lock
    were held. The runtime sanitizer cannot see this contract, so it is a
    lint-only escape hatch for private helpers.

``# pegasus-lint: disable=PG001,PG004 <reason>``
    Suppress those rules on this line (or the line below, when the comment
    stands alone). The reason is MANDATORY — a bare disable is itself a
    finding (PG000).

``# pegasus-lint: disable-block=PG004 <reason>``
    Same, but on a compound statement's header line it suppresses the whole
    statement body (e.g. one justified ``with ctr.lock:`` in a traced
    forward instead of a comment per mutated counter).
"""

from __future__ import annotations

import re

RULES = {
    "PG000": "malformed suppression or annotation (disable= needs rule IDs "
             "and a written reason; guarded-by must sit on an attribute "
             "assignment)",
    "PG001": "jax dispatch, plan build, or blocking call inside a "
             "`with <lock>:` body",
    "PG002": "attribute annotated `# guarded-by: <lock>` touched without "
             "holding that lock",
    "PG003": "lock acquired against the declared hierarchy "
             "(registry -> scheduler -> counters)",
    "PG004": "impure operation inside a jitted forward / Pallas kernel "
             "body, or a donated buffer read after the jitted call",
}

# Condition variables share their underlying lock: holding or acquiring the
# condition IS holding the lock. Both the scheduler (_space/_work on _lock)
# and the device pool (_work on _lock) follow this naming.
LOCK_ALIASES = {
    "_space": "_lock",
    "_work": "_lock",
}

# The declared acquisition hierarchy, OUTER to INNER. Static form: keyed by
# (module stem, canonical lock attribute name) — PG003 checks syntactic
# nesting within one module, so each module sees only its own ranks.
# Runtime form (LOCK_RANKS): keyed by the qualified name passed to
# sanitizer.make_lock(), so the InstrumentedLock graph checks nesting
# ACROSS modules (e.g. registry.stats() holding registry._lock while
# compile_stats() takes the plan counter lock is legal: rank 0 -> rank 5).
STATIC_LOCK_ORDER = {
    ("registry", "_lock"): 0,
    ("scheduler", "_lock"): 1,
    ("serve", "_ctr_lock"): 2,
    ("devices", "_lock"): 3,
    ("plan", "_replica_lock"): 4,
    ("plan", "lock"): 5,          # _PlanCounters.lock — the innermost lock
    # self-healing layer (ISSUE 9): breaker state is queried under
    # devices._lock in stream placement (3 → 6) and never wraps another
    # lock; the injector lock only guards spec matching/counting.
    ("health", "_lock"): 6,
    ("chaos", "_lock"): 7,
}

LOCK_RANKS = {
    "registry._lock": 0,
    "scheduler._lock": 1,
    "serve._ctr_lock": 2,
    "devices._lock": 3,
    "plan._replica_lock": 4,
    "plan._ctr.lock": 5,
    "health._lock": 6,
    "chaos._lock": 7,
}

# -- PG001 classification ---------------------------------------------------

# Any call rooted at these names is jax dispatch (device transfer, tracing,
# or execution) — multi-millisecond work that must not run under a lock.
JAX_ROOTS = frozenset({"jax", "jnp"})

# Plan construction entry points: a compile under a lock stalls every
# other thread for seconds (the registry builds OUTSIDE its lock for
# exactly this reason).
PLAN_CALLS = frozenset({"build_plan", "plan_for"})

# Dotted calls that block the calling thread outright.
BLOCKING_DOTTED = frozenset({"time.sleep", "concurrent.futures.wait"})

# Final attribute names that block: thread.join() and future.result().
# (str.join on a literal separator is exempted by the walker; Condition
# .wait() is NOT listed — it releases the lock while parked, which is the
# one legitimate way to sleep under a lock.)
BLOCKING_FINAL_ATTRS = frozenset({"join", "result"})

# Receiver-sensitive blocking methods: ``.get()``/``.put()`` block only on
# queue-like receivers and ``.wait()`` only on event-like ones — dict.get
# and Condition.wait must stay exempt (the latter releases the lock while
# parked). Static analysis cannot type the receiver, so the walker matches
# the receiver's FINAL name component (case-insensitive substring) against
# these hints — the same name-convention contract the whole lint rests on
# (locks end in "lock", queues carry "queue"/"_q", events "event"/"done").
BLOCKING_RECEIVER_HINTS = {
    "get": ("queue", "inbox", "mailbox", "_q"),
    "put": ("queue", "inbox", "mailbox", "_q"),
    "wait": ("event", "evt", "done", "ready", "stopped", "barrier"),
}


def blocking_receiver(attr: str, receiver: str | None,
                      n_pos_args: int = 0) -> bool:
    """True when ``receiver.attr(...)`` matches the queue/event blocking
    table: ``queue.Queue.get/put`` and ``threading.Event.wait`` under a
    lock park the holder while every other thread spins on the lock.

    Two disambiguations keep ``dict.get`` exempt: a blocking ``Queue.get()``
    takes no positional argument (``dict.get(key)`` always does), and a
    PLURAL queue-like name (``_queues``) is a container of queues — its
    ``.get``/``.put`` are the dict's, not a queue's."""
    hints = BLOCKING_RECEIVER_HINTS.get(attr)
    if not hints or not receiver:
        return False
    if attr == "get" and n_pos_args:
        return False
    low = receiver.lower()
    if attr in ("get", "put") and low.endswith("s"):
        return False
    for h in hints:
        if h.startswith("_"):          # suffix hints: "work_q", or bare "q"
            if low == h.lstrip("_") or low.endswith(h):
                return True
        elif h in low:
            return True
    return False

# -- PG004 classification ---------------------------------------------------

# Whole-plan forwards are found three ways: by convention every structural
# forward is a local function with one of these names; by being the first
# argument of jax.jit(...); or by being the (possibly functools.partial-
# wrapped) first argument of pl.pallas_call(...).
PURE_FUNC_NAMES = frozenset({"forward", "_pure"})

# Call roots that are side-effecting / nondeterministic at trace time.
IMPURE_ROOTS = frozenset({"time", "random"})
IMPURE_DOTTED_PREFIXES = (("np", "random"), ("numpy", "random"))
IMPURE_BUILTINS = frozenset({"print", "open", "input"})

# Method names that mutate their receiver — calling one on a NONLOCAL
# object from inside a traced body is a trace-time side effect.
MUTATOR_METHODS = frozenset({
    "add", "append", "appendleft", "extend", "extendleft", "update",
    "setdefault", "pop", "popleft", "popitem", "remove", "discard",
    "clear", "insert",
})

# Roots whose attribute calls are pure array ops, never receiver mutation
# (jnp.add is addition, not set.add).
SAFE_MUTATOR_ROOTS = frozenset({"jax", "jnp", "np", "numpy", "pl",
                                "functools", "math", "lax"})

# -- PGA1xx: plan-audit policy (repro.analysis.planaudit) -------------------

# The plan auditor walks a COMPILED ExecutionPlan (banks, fused stacks,
# bucket ladder, q8 tables) instead of source text; its findings carry the
# PGA1xx namespace so lint (PG0xx) and audit reports never collide.
PGA_RULES = {
    "PGA101": "fixed-point overflow: the worst-case int32 accumulator bound "
              "of a bank's q8 tables (all groups rescaled to the finest "
              "group scale) exceeds int32 (error) or is within 2x of it "
              "(warning)",
    "PGA102": "quantization fidelity: a bank's worst-case q8 dequantization "
              "error vs its f32 LUT exceeds the configured per-group "
              "relative tolerance (stale/tampered q8 table)",
    "PGA103": "VMEM footprint: a pallas_call's worst-case working set "
              "(operand blocks + stacked tables) exceeds the per-target "
              "VMEM budget (error) or is within the margin of it (warning)",
    "PGA104": "kernel-tile alignment: a ladder bucket dispatches hidden pad "
              "rows (bucket not divisible by the batch tile), or an "
              "mxu-strategy LUT width misses 128-lane alignment",
    "PGA105": "fusion rejection: an adjacent chained bank pair did not fuse "
              "(v/C mismatch, chaining break, nmax_cap split, fuse=False, "
              "or a family builder without the fusion pass)",
    "PGA106": "dataplane resource fit: the plan lowered to a MAT pipeline "
              "exceeds the declared switch target's SRAM/TCAM/bus/PHV "
              "budget (error); recirculation passes are a warning",
}

INT32_MAX = 2**31 - 1

# PGA101: warn when the overflow bound is within this factor of int32.
PGA101_MARGIN = 2.0

# PGA102: max per-group relative dequant error. Symmetric int8
# round-to-nearest guarantees err <= scale/2 = amax/254 (~0.4% of the
# group's amax); 1% only trips when the q8 table no longer matches the f32
# LUT it claims to quantize.
PGA102_REL_TOL = 1.0 / 100.0

# PGA103: per-core VMEM budget (bytes) and warn margin. ~16 MB/core is the
# common TPU figure; override per target via AuditConfig.
PGA103_VMEM_BUDGET = 16 * 2**20
PGA103_MARGIN = 2.0

# PGA104: MXU lane width the mxu strategy wants LUT columns aligned to.
MXU_LANES = 128

# -- comment grammar --------------------------------------------------------

GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][\w]*)")
HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_][\w]*)")
SUPPRESS_RE = re.compile(
    r"#\s*pegasus-lint:\s*(disable|disable-block)=([A-Za-z0-9,]*)\s*(.*)")


def canonical_lock(name: str) -> str | None:
    """Canonical lock name for an attribute name, or None if it is not a
    lock: condition aliases map to their lock, and anything else must end
    in ``lock`` (``_lock``, ``_ctr_lock``, ``lock``, ...)."""
    name = LOCK_ALIASES.get(name, name)
    return name if name.lower().endswith("lock") else None


def static_ranks_for_module(stem: str) -> dict[str, int]:
    """``{lock attribute name: rank}`` for one module's PG003 check."""
    return {attr: rank for (mod, attr), rank in STATIC_LOCK_ORDER.items()
            if mod == stem}
