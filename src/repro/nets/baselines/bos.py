"""BoS baseline (paper §2): binary RNN via input→output bypass tables.

BoS stores the full mapping from (binary hidden state, binary step input) to
the next binary hidden state in dataplane tables — full-precision INSIDE the
recurrence, but activations binarized at every table boundary, and the input
restricted to a few bits per step (paper: 18-bit total input scale; 2^n
entries for an n-bit key is the scalability wall).

We train the binarized-activation RNN with STE and evaluate its exact binary
forward — which is bit-identical to what the enumerated bypass tables would
produce, since the tables simply memoize this function. ``bos_table_entries``
reports the enumeration cost that limits BoS's input scale.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..common import train_classifier
from .n3ic import binarize

__all__ = ["BoS", "train_bos", "bos_apply", "bos_table_entries"]

HIDDEN_BITS = 8        # binary hidden state width (paper's moderate config)
LEN_BITS = 2           # packet-length bucket bits per step
IPD_BITS = 1           # IPD bucket bits per step
WINDOW = 6             # 6 × 3 = 18-bit input scale, as in the paper


@dataclasses.dataclass
class BoS:
    params: dict
    num_classes: int


def _bucketize(x: jax.Array) -> jax.Array:
    """[B, W, 2] bytes → [B, WINDOW, LEN_BITS+IPD_BITS] ±1 bits."""
    xw = x[:, :WINDOW].astype(jnp.float32)
    len_q = jnp.floor(xw[..., 0] / 64.0)                  # 2 bits: 4 buckets
    ipd_q = jnp.floor(xw[..., 1] / 128.0)                 # 1 bit: 2 buckets
    bits = []
    for b in range(LEN_BITS):
        bits.append(jnp.mod(jnp.floor(len_q / 2**b), 2))
    for b in range(IPD_BITS):
        bits.append(jnp.mod(jnp.floor(ipd_q / 2**b), 2))
    return 2.0 * jnp.stack(bits, axis=-1) - 1.0


def init_bos(num_classes: int, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    in_bits = LEN_BITS + IPD_BITS
    return {
        "w_x": jax.random.normal(ks[0], (in_bits, HIDDEN_BITS)) / np.sqrt(in_bits),
        "w_h": jax.random.normal(ks[1], (HIDDEN_BITS, HIDDEN_BITS)) / np.sqrt(HIDDEN_BITS),
        "b": jnp.zeros(HIDDEN_BITS),
        "w_o": jax.random.normal(ks[2], (HIDDEN_BITS, num_classes)) / np.sqrt(HIDDEN_BITS),
    }


def bos_apply(p_or_bundle, x: jax.Array) -> jax.Array:
    """Binary-state recurrence: h is ±1 bits at every step (table boundary)."""
    p = p_or_bundle.params if isinstance(p_or_bundle, BoS) else p_or_bundle
    xb = _bucketize(x)                                    # [B, W, 3] ±1
    h = jnp.ones((x.shape[0], HIDDEN_BITS))
    for t in range(WINDOW):
        # full precision inside; binarized at the output boundary
        h = binarize(xb[:, t] @ p["w_x"] + h @ p["w_h"] + p["b"])
    return h @ p["w_o"]


def train_bos(x: np.ndarray, y: np.ndarray, num_classes: int, *, steps=900, seed=0) -> BoS:
    params = init_bos(num_classes, seed)
    params = train_classifier(params, bos_apply, x, y, steps=steps, lr=5e-3,
                              weight_decay=0.0, seed=seed)
    return BoS(params=params, num_classes=num_classes)


def bos_table_entries() -> int:
    """Bypass-table enumeration: 2^(hidden+input) entries per step table."""
    return 2 ** (HIDDEN_BITS + LEN_BITS + IPD_BITS)
