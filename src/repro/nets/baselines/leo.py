"""Leo baseline (paper §2): decision tree classifier at line rate.

A plain CART (gini) tree on statistical features — numpy implementation,
depth/leaf-count capped to the paper's "1024 nodes" resource-evaluation
configuration. Trees ARE MAT-friendly (that's Leo's whole design), so no
deployment gap: evaluated accuracy == dataplane accuracy.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LeoTree", "train_leo", "leo_predict"]


@dataclasses.dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    label: int = -1


@dataclasses.dataclass
class LeoTree:
    nodes: list[_Node]
    num_classes: int

    @property
    def node_count(self) -> int:
        return len(self.nodes)


def _gini(counts: np.ndarray) -> float:
    n = counts.sum()
    if n == 0:
        return 0.0
    p = counts / n
    return float(1.0 - (p * p).sum())


def _best_split(x: np.ndarray, y: np.ndarray, n_classes: int, max_thresholds=32):
    n, d = x.shape
    best = None
    parent = _gini(np.bincount(y, minlength=n_classes))
    for j in range(d):
        order = np.argsort(x[:, j], kind="stable")
        xs, ys = x[order, j], y[order]
        distinct = np.nonzero(xs[1:] > xs[:-1])[0]
        if distinct.size == 0:
            continue
        if distinct.size > max_thresholds:
            sel = np.linspace(0, distinct.size - 1, max_thresholds).astype(int)
            distinct = distinct[sel]
        onehot = np.zeros((n, n_classes))
        onehot[np.arange(n), ys] = 1.0
        cum = np.cumsum(onehot, axis=0)
        total = cum[-1]
        for i in distinct:
            nl = i + 1
            cl = cum[i]
            cr = total - cl
            g = (nl * _gini(cl) + (n - nl) * _gini(cr)) / n
            if best is None or g < best[2]:
                best = (j, 0.5 * (xs[i] + xs[i + 1]), g)
    if best is None or best[2] >= parent - 1e-9:
        return None
    return best


def train_leo(
    x: np.ndarray, y: np.ndarray, num_classes: int,
    *, max_nodes: int = 1024, min_samples: int = 8,
) -> LeoTree:
    x = x.astype(np.float32)
    y = y.astype(np.int64)
    nodes: list[_Node] = [_Node()]
    queue = [(0, np.arange(len(y)))]
    while queue and len(nodes) < max_nodes:
        nid, idx = queue.pop(0)
        counts = np.bincount(y[idx], minlength=num_classes)
        nodes[nid].label = int(counts.argmax())
        if len(idx) < min_samples or counts.max() == counts.sum():
            continue
        split = _best_split(x[idx], y[idx], num_classes)
        if split is None:
            continue
        j, thr, _ = split
        mask = x[idx, j] <= thr
        li, ri = len(nodes), len(nodes) + 1
        nodes[nid].feature, nodes[nid].threshold = j, float(thr)
        nodes[nid].left, nodes[nid].right = li, ri
        nodes.append(_Node())
        nodes.append(_Node())
        queue.append((li, idx[mask]))
        queue.append((ri, idx[~mask]))
    return LeoTree(nodes=nodes, num_classes=num_classes)


def leo_predict(tree: LeoTree, x: np.ndarray) -> np.ndarray:
    x = x.astype(np.float32)
    out = np.zeros(len(x), np.int64)
    for i, row in enumerate(x):
        n = 0
        while tree.nodes[n].left != -1:
            nd = tree.nodes[n]
            n = nd.left if row[nd.feature] <= nd.threshold else nd.right
        out[i] = tree.nodes[n].label
    return out
