"""N3IC baseline (paper §2): fully binarized MLP — XNOR + popcount MatMul.

Binary network semantics: weights and activations in {-1, +1}; a dot product
of ±1 vectors of length n equals ``2·popcount(XNOR(a, b)) − n`` — the
dataplane-executable form N3IC uses. We train with straight-through
estimators and evaluate with the exact binary forward, so the reported
accuracy is what the switch deployment would produce.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..common import train_classifier

__all__ = ["N3IC", "train_n3ic", "n3ic_apply", "n3ic_model_bits"]

HIDDEN = 64  # binary nets need width to compensate — paper's N3IC is 24.4Kb


@dataclasses.dataclass
class N3IC:
    params: dict
    num_classes: int
    mu: np.ndarray
    sigma: np.ndarray


@jax.custom_vjp
def binarize(x):
    return jnp.sign(x) + (x == 0)  # sign with 0 → +1


def _bin_fwd(x):
    return binarize(x), x


def _bin_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0),)  # clipped STE


binarize.defvjp(_bin_fwd, _bin_bwd)


def init_n3ic(in_dim: int, num_classes: int, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "w0": jax.random.normal(ks[0], (in_dim, HIDDEN)) / np.sqrt(in_dim),
        "w1": jax.random.normal(ks[1], (HIDDEN, HIDDEN)) / np.sqrt(HIDDEN),
        "w2": jax.random.normal(ks[2], (HIDDEN, num_classes)) / np.sqrt(HIDDEN),
    }


def n3ic_apply(bundle_or_params, x: jax.Array, mu=None, sigma=None) -> jax.Array:
    """Binary forward: popcount-equivalent ±1 matmuls, binary activations.

    Input binarization: each feature is thresholded at its training mean
    (N3IC's input bit-vector construction). No BN/Act layers — N3IC does not
    support them (the paper's generality critique).
    """
    if isinstance(bundle_or_params, N3IC):
        p, mu, sigma = bundle_or_params.params, bundle_or_params.mu, bundle_or_params.sigma
    else:
        p = bundle_or_params
    xb = binarize((x.astype(jnp.float32) - mu) / sigma)
    h = binarize(xb @ binarize(p["w0"]))
    h = binarize(h @ binarize(p["w1"]))
    return h @ binarize(p["w2"])  # integer popcount scores as logits


def train_n3ic(x: np.ndarray, y: np.ndarray, num_classes: int, *, steps=900, seed=0) -> N3IC:
    mu = x.astype(np.float32).mean(0)
    sigma = x.astype(np.float32).std(0) + 1e-3
    params = init_n3ic(x.shape[1], num_classes, seed)
    params = train_classifier(
        params, lambda p, xb: n3ic_apply(p, xb, mu, sigma), x, y,
        steps=steps, lr=5e-3, weight_decay=0.0, seed=seed,
    )
    return N3IC(params=params, num_classes=num_classes, mu=mu, sigma=sigma)


def n3ic_model_bits(m: N3IC) -> int:
    """1 bit per weight (the binary model the switch stores)."""
    return sum(int(np.prod(w.shape)) for w in m.params.values())
