"""CNN-B / CNN-M / CNN-L (paper §6.3): 1-D textcnn-style classifiers.

  * CNN-B: Basic Fusion only — conv windows over the (len, IPD) sequence,
    each window position a fused table bank, ReLU folded forward, avg-pool +
    FC head.
  * CNN-M: same input, Advanced Primitive Fusion (NAM): ALL intermediate
    SumReduces removed — each window's whole sub-network folds into ONE
    lookup; a single final SumReduce mixes window contributions. Bigger
    effective model (deeper per-window sub-nets) at LOWER lookup cost.
  * CNN-L: NAM over PACKETS with raw 60-byte payloads (+len,ipd): a
    per-packet encoder (trained jointly) produces a compact embedding that
    is fuzzy-indexed to a few bits — this is the paper's per-flow
    "fuzzy index per packet" storage trick (§7.3, Fig. 7) — and a second
    level maps (packet-slot, index) → class-logit contributions.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import (
    PegasusLinear,
    init_pegasus_bank,
    init_pegasus_linear,
)
from repro.core.fuzzy_tree import FuzzyTree, fit_tree
from repro.engine import plan_for

from .common import train_classifier

__all__ = [
    "CNNModel", "train_cnn", "cnn_apply",
    "pegasusify_cnn", "pegasus_cnn_apply",
    "CNNL", "train_cnn_l", "cnn_l_apply", "pegasusify_cnn_l", "pegasus_cnn_l_apply",
]


# ---------------------------------------------------------------------------
# CNN-B / CNN-M: conv over the 8×2 sequence
# ---------------------------------------------------------------------------

KERNEL = 3  # conv window length (time steps)


@dataclasses.dataclass
class CNNModel:
    params: dict
    num_classes: int
    channels: int
    hidden: int
    size: str  # "B" | "M"


def init_cnn(num_classes: int, channels: int, hidden: int, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    in_w = KERNEL * 2  # window of 3 steps × (len, ipd)
    return {
        "w_conv": jax.random.normal(ks[0], (in_w, channels)) / np.sqrt(in_w),
        "b_conv": jnp.zeros(channels),
        "w_h": jax.random.normal(ks[1], (channels, hidden)) / np.sqrt(channels),
        "b_h": jnp.zeros(hidden),
        "w_o": jax.random.normal(ks[2], (hidden, num_classes)) / np.sqrt(hidden),
        "b_o": jnp.zeros(num_classes),
    }


def _windows(x: jax.Array) -> jax.Array:
    """[B, W, 2] → [B, P, KERNEL*2] sliding windows (stride 1)."""
    b, w, f = x.shape
    p = w - KERNEL + 1
    idx = jnp.arange(p)[:, None] + jnp.arange(KERNEL)[None, :]
    return x[:, idx].reshape(b, p, KERNEL * f)


def cnn_apply(m_or_p, x: jax.Array) -> jax.Array:
    p = m_or_p.params if isinstance(m_or_p, CNNModel) else m_or_p
    xf = x.astype(jnp.float32) / 255.0
    win = _windows(xf)                                   # [B, P, 6]
    h = jax.nn.relu(win @ p["w_conv"] + p["b_conv"])     # conv as per-window FC
    h = h.mean(axis=1)                                   # avg pool over time
    h = jax.nn.relu(h @ p["w_h"] + p["b_h"])
    return h @ p["w_o"] + p["b_o"]


def train_cnn(
    x: np.ndarray, y: np.ndarray, num_classes: int, *, size: str = "B", steps=900, seed=0
) -> CNNModel:
    channels, hidden = (16, 24) if size == "B" else (48, 64)
    params = init_cnn(num_classes, channels, hidden, seed=seed)
    params = train_classifier(params, cnn_apply, x, y, steps=steps, lr=2e-3, seed=seed)
    return CNNModel(params=params, num_classes=num_classes, channels=channels, hidden=hidden, size=size)


@dataclasses.dataclass
class PegasusCNN:
    """CNN-B: fused banks. CNN-M (NAM): window_bank covers the whole
    per-window sub-model in ONE lookup per window."""

    window_bank: PegasusLinear      # [B,P,6] windows → per-window contribution
    head_banks: list[PegasusLinear]  # empty for NAM (M); B keeps FC head banks
    out_bias: jax.Array | None
    nam: bool
    pool_windows: int


def pegasusify_cnn(
    m: CNNModel, x_calib: np.ndarray, *, depth: int = 12, refine_steps: int = 0
) -> PegasusCNN:
    p = m.params
    xf = x_calib.astype(np.float32)
    win = np.asarray(_windows(jnp.asarray(xf)))          # [B, P, 6]
    flat = win.reshape(-1, KERNEL * 2)
    n_pool = win.shape[1]

    if m.size == "M":
        # NAM (Advanced Fusion ③): the per-window sub-model — conv, ReLU, FC,
        # ReLU, FC head — folds into ONE lookup; only the final SumReduce
        # over windows survives.
        def submodel(c):  # c: [1, C, 6] centroids → [1, C, classes]
            h = jax.nn.relu(c / 255.0 @ p["w_conv"] + p["b_conv"])
            h = jax.nn.relu(h @ p["w_h"] + p["b_h"]) / n_pool
            return h @ p["w_o"]

        bank = init_pegasus_bank(
            submodel, flat, group_size=KERNEL * 2, depth=depth, bias=None
        )
        peg = PegasusCNN(
            window_bank=bank, head_banks=[], out_bias=p["b_o"],
            nam=True, pool_windows=n_pool,
        )
        if refine_steps:
            from repro.core.finetune import refine

            # per-window distillation target through the NAM decomposition
            per_win_tgt = (
                jax.nn.relu(
                    jax.nn.relu(jnp.asarray(flat) / 255.0 @ p["w_conv"] + p["b_conv"])
                    @ p["w_h"] + p["b_h"]
                ) / n_pool
            ) @ p["w_o"]
            peg.window_bank = refine(bank, jnp.asarray(flat), per_win_tgt, steps=refine_steps)
        return peg

    # CNN-B (Basic Fusion): conv window is ONE group (K=1) → the ReLU folds
    # directly into the rows: rows = relu(c@W + b).
    conv_bank = init_pegasus_bank(
        lambda c: jax.nn.relu(c / 255.0 @ p["w_conv"] + p["b_conv"]),
        flat, group_size=KERNEL * 2, depth=depth, bias=None,
    )
    pooled = np.asarray(
        jax.nn.relu(jnp.asarray(flat) / 255.0 @ p["w_conv"] + p["b_conv"])
    ).reshape(win.shape[0], n_pool, -1).mean(1)          # post-relu avg pool
    h_bank = init_pegasus_linear(
        np.asarray(p["w_h"], np.float32), np.asarray(p["b_h"], np.float32),
        pooled, group_size=1, depth=8, lut_bits=None,
    )
    h_pre = np.asarray(jnp.asarray(pooled) @ p["w_h"] + p["b_h"])
    # head banks: 1-D groups — exact for the linear part (a table per
    # scalar unit, 2^8 entries: the paper's fixed-point activation story)
    o_bank = init_pegasus_linear(
        np.asarray(p["w_o"], np.float32), np.asarray(p["b_o"], np.float32),
        h_pre, group_size=1, depth=8, lut_bits=None,
        act_fn=lambda c: jnp.maximum(c, 0),
    )
    return PegasusCNN(
        window_bank=conv_bank, head_banks=[h_bank, o_bank], out_bias=None,
        nam=False, pool_windows=n_pool,
    )


def pegasus_cnn_apply(peg: PegasusCNN, x: jax.Array, *, backend: str = "gather",
                      jit: bool = False) -> jax.Array:
    """Windowed deployment forward via the engine (B and M/NAM variants).
    Eager by default — one-shot evaluation entry point; serving call sites
    (PegasusServer / build_plan) get the jitted path."""
    return plan_for(peg)(x, backend=backend, jit=jit)


# ---------------------------------------------------------------------------
# CNN-L: NAM over packets with raw payload bytes
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CNNL:
    params: dict
    num_classes: int
    emb_dim: int


def init_cnn_l(num_classes: int, emb_dim: int = 16, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    in_dim = 62  # 60 payload bytes + len + ipd
    return {
        "w_e1": jax.random.normal(ks[0], (in_dim, 64)) / np.sqrt(in_dim),
        "b_e1": jnp.zeros(64),
        "w_e2": jax.random.normal(ks[1], (64, emb_dim)) / np.sqrt(64.0),
        "b_e2": jnp.zeros(emb_dim),
        "w_o": jax.random.normal(ks[2], (emb_dim, num_classes)) / np.sqrt(float(emb_dim)),
        "b_o": jnp.zeros(num_classes),
    }


def _packet_feats(seq: jax.Array, payload: jax.Array) -> jax.Array:
    """[B,W,2]+[B,W,60] → [B, W, 62] float in [0,1]."""
    return jnp.concatenate(
        [payload.astype(jnp.float32), seq.astype(jnp.float32)], axis=-1
    ) / 255.0


def cnn_l_apply(m_or_p, seq: jax.Array, payload: jax.Array) -> jax.Array:
    p = m_or_p.params if isinstance(m_or_p, CNNL) else m_or_p
    x = _packet_feats(seq, payload)                       # [B, W, 62]
    h = jax.nn.relu(x @ p["w_e1"] + p["b_e1"])
    e = jnp.tanh(h @ p["w_e2"] + p["b_e2"])               # per-packet embedding
    logits_per_pkt = e @ p["w_o"]                         # NAM contributions
    return logits_per_pkt.sum(axis=1) + p["b_o"]


def train_cnn_l(
    seq: np.ndarray, payload: np.ndarray, y: np.ndarray, num_classes: int,
    *, steps=1000, seed=0,
) -> CNNL:
    params = init_cnn_l(num_classes, seed=seed)
    x_pack = np.concatenate([seq.reshape(len(y), -1), payload.reshape(len(y), -1)], axis=1)
    w = seq.shape[1]

    def apply_packed(p, xb):
        s = xb[:, : w * 2].reshape(-1, w, 2)
        pl = xb[:, w * 2 :].reshape(-1, w, 60)
        return cnn_l_apply(p, s, pl)

    params = train_classifier(params, apply_packed, x_pack, y, steps=steps, lr=2e-3, seed=seed)
    return CNNL(params=params, num_classes=num_classes, emb_dim=16)


@dataclasses.dataclass
class PegasusCNNL:
    """Two-level NAM: per-packet encoder banks → fuzzy index (stored per
    flow, 4–8 bits, the §7.3 flow-storage trick) → logit LUT, final SumReduce."""

    bank1: PegasusLinear           # raw 62 bytes → encoder layer-1 pre-act
    bank2: PegasusLinear           # layer-1 pre-act → embedding pre-act (ReLU folded)
    emb_tree: FuzzyTree            # fuzzy index over tanh(embedding)
    logit_lut: jax.Array           # [2^index_bits, num_classes]
    bias: jax.Array
    index_bits: int


def pegasusify_cnn_l(
    m: CNNL, seq: np.ndarray, payload: np.ndarray, *,
    enc_group: int = 1, enc_depth: int = 8, index_bits: int = 4,
) -> PegasusCNNL:
    p = m.params
    x = np.asarray(_packet_feats(jnp.asarray(seq), jnp.asarray(payload)))  # [B,W,62]
    flat = x.reshape(-1, 62) * 255.0  # raw byte domain for the tables

    # level-1 bank: raw packet bytes (31 groups × 2 bytes) → layer-1 pre-act
    bank1 = init_pegasus_linear(
        np.asarray(p["w_e1"], np.float32) / 255.0, np.asarray(p["b_e1"], np.float32),
        flat, group_size=enc_group, depth=enc_depth, lut_bits=None,
    )
    h_pre = np.asarray(jnp.asarray(flat) / 255.0 @ p["w_e1"] + p["b_e1"])
    # level-1b bank: pre-act → embedding pre-act, ReLU folded into LUT rows
    bank2 = init_pegasus_linear(
        np.asarray(p["w_e2"], np.float32), np.asarray(p["b_e2"], np.float32),
        h_pre, group_size=enc_group, depth=enc_depth, lut_bits=None,
        act_fn=lambda c: jnp.maximum(c, 0.0),
    )

    # level-2: fuzzy-index tanh(embedding) to ``index_bits`` bits per packet;
    # the per-flow register stores ONLY this index (Fig. 7 storage model).
    emb = np.asarray(
        jnp.tanh(jax.nn.relu(jnp.asarray(h_pre)) @ p["w_e2"] + p["b_e2"])
    )
    emb_tree = fit_tree(emb, depth=index_bits)
    logit_lut = jnp.asarray(emb_tree.centroids) @ p["w_o"]
    return PegasusCNNL(
        bank1=bank1, bank2=bank2, emb_tree=emb_tree, logit_lut=logit_lut,
        bias=p["b_o"], index_bits=index_bits,
    )


def pegasus_cnn_l_apply(
    peg: PegasusCNNL, seq: jax.Array, payload: jax.Array, *,
    backend: str = "gather", jit: bool = False
) -> jax.Array:
    """Deployment forward via the engine: all-table encoding → fuzzy index →
    LUT sum (the two-level NAM). Eager by default — one-shot evaluation
    entry point; serving call sites get the jitted path."""
    return plan_for(peg)(seq, payload, backend=backend, jit=jit)
