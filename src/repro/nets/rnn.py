"""RNN-B (paper §6.3): windowed recurrent classifier over (len, IPD) steps.

Follows BoS's *windowed* design: the switch unrolls all W time steps in the
pipeline (no hidden-state write-back); Pegasus upgrades it from binary to
fixed-point with fuzzy-matched tables.

Dense teacher:  h_t = tanh(Emb(x_t) + h_{t-1} @ W_h + b),  logits = h_W @ W_o.
Pegasus form, per step: one table bank indexed on the RAW 2-byte step input
(exactly the Emb∘proj fusion — Embedding Lookup IS a Map) plus one bank
indexed on h_{t-1}; their SumReduce feeds tanh, which folds into the NEXT
step's tables (Basic Fusion). Final classifier bank folds tanh → W_o.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, init_pegasus_linear
from repro.engine import plan_for

from .common import train_classifier

__all__ = ["RNNB", "train_rnn", "rnn_apply", "pegasusify_rnn", "pegasus_rnn_apply"]

HIDDEN = 24


@dataclasses.dataclass
class RNNB:
    params: dict
    num_classes: int
    window: int


def init_rnn(num_classes: int, hidden: int = HIDDEN, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        # Emb-as-projection of the 2 raw byte features (len, ipd)
        "w_x": jax.random.normal(ks[0], (2, hidden)) / np.sqrt(2.0),
        "w_h": jax.random.normal(ks[1], (hidden, hidden)) / np.sqrt(hidden),
        "b": jnp.zeros(hidden),
        "w_o": jax.random.normal(ks[2], (hidden, num_classes)) / np.sqrt(hidden),
        "b_o": jnp.zeros(num_classes),
    }


def rnn_apply(p: dict, x: jax.Array) -> jax.Array:
    """x: [B, W, 2] uint8 → logits. Normalizes bytes to [0,1] internally."""
    xf = x.astype(jnp.float32) / 255.0
    b, w, _ = xf.shape
    h = jnp.zeros((b, HIDDEN))
    for t in range(w):
        h = jnp.tanh(xf[:, t] @ p["w_x"] + h @ p["w_h"] + p["b"])
    return h @ p["w_o"] + p["b_o"]


def train_rnn(x: np.ndarray, y: np.ndarray, num_classes: int, *, steps=900, seed=0) -> RNNB:
    params = init_rnn(num_classes, seed=seed)
    params = train_classifier(params, rnn_apply, x, y, steps=steps, lr=2e-3, seed=seed)
    return RNNB(params=params, num_classes=num_classes, window=x.shape[1])


# ---------------------------------------------------------------------------
# Pegasusification
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PegasusRNN:
    """Per-step table banks. Step t's recurrent bank folds tanh of h_pre."""

    x_banks: list[PegasusLinear]   # one per step, indexed on raw (len, ipd)
    h_banks: list[PegasusLinear]   # steps 1..W-1, indexed on h_pre_{t-1}
    out_bank: PegasusLinear        # classifier, indexed on h_pre_{W-1}
    window: int


def _pre_activations(bundle: RNNB, x: np.ndarray) -> list[np.ndarray]:
    p = bundle.params
    xf = jnp.asarray(x, jnp.float32) / 255.0
    b, w, _ = xf.shape
    pres = []
    h = jnp.zeros((b, HIDDEN))
    for t in range(w):
        pre = xf[:, t] @ p["w_x"] + h @ p["w_h"] + p["b"]
        pres.append(np.asarray(pre))
        h = jnp.tanh(pre)
    return pres


def pegasusify_rnn(
    bundle: RNNB,
    x_calib: np.ndarray,
    *,
    depth: int = 8,
    h_group: int = 1,
    x_group: int = 1,
    refine_steps: int = 0,
) -> PegasusRNN:
    p = bundle.params
    w = bundle.window
    pres = _pre_activations(bundle, x_calib)
    scale = 1.0 / 255.0

    x_banks, h_banks = [], []
    for t in range(w):
        # raw 2-byte step input is ONE partition group (v=2): Emb-style Map
        xc = x_calib[:, t].astype(np.float32)
        bias_t = np.asarray(p["b"], np.float32) if t == 0 else None
        x_banks.append(
            init_pegasus_linear(
                np.asarray(p["w_x"], np.float32) * scale, bias_t, xc,
                group_size=x_group, depth=depth, lut_bits=None,
            )
        )
        if t > 0:
            # recurrent bank: index on h_pre_{t-1}, fold tanh + bias
            h_banks.append(
                init_pegasus_linear(
                    np.asarray(p["w_h"], np.float32),
                    np.asarray(p["b"], np.float32),
                    pres[t - 1],
                    group_size=h_group, depth=depth, lut_bits=None,
                    act_fn=jnp.tanh,
                )
            )
    out_bank = init_pegasus_linear(
        np.asarray(p["w_o"], np.float32), np.asarray(p["b_o"], np.float32),
        pres[-1], group_size=h_group, depth=depth, lut_bits=None,
        act_fn=jnp.tanh,
    )
    peg = PegasusRNN(x_banks=x_banks, h_banks=h_banks, out_bank=out_bank, window=w)

    if refine_steps:
        from repro.core.finetune import refine

        for t in range(1, w):
            peg.h_banks[t - 1] = refine(
                peg.h_banks[t - 1], jnp.asarray(pres[t - 1]),
                jnp.asarray(pres[t]) - jnp.asarray(x_calib[:, t], jnp.float32) @ (np.asarray(p["w_x"]) * scale),
                steps=refine_steps,
            )
    return peg


def pegasus_rnn_apply(peg: PegasusRNN, x: jax.Array, *, backend: str = "gather",
                      jit: bool = False) -> jax.Array:
    """Hard-routed deployment forward via the engine. x: [B, W, 2] uint8.

    Eager by default: this is the one-shot evaluation entry point, and a
    whole-plan XLA compile never amortizes over a single call — serving
    call sites (PegasusServer / build_plan) get the jitted path."""
    return plan_for(peg)(x, backend=backend, jit=jit)
