"""MLP-B (paper §6.3): BN→FC→ReLU ×3 + classifier head, on statistical
features — with its fully fused Pegasus form.

Fusion layout (Basic Primitive Fusion, Fig. 5 ①): each deployed table bank i
is indexed by layer i-1's PRE-activation and folds
`[ReLU →] BN-affine → FC` into its LUT rows; the switch executes
K lookups + a SumReduce per bank — nothing else.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, init_pegasus_linear
from repro.engine import plan_for

from .common import train_classifier

__all__ = ["MLPB", "init_mlp", "mlp_apply", "train_mlp", "pegasusify_mlp", "pegasus_mlp_apply"]

HIDDEN = 32


@dataclasses.dataclass
class MLPB:
    """Dense teacher + feature-normalization constants."""

    params: dict
    mu: np.ndarray
    sigma: np.ndarray
    num_classes: int


def init_mlp(in_dim: int, num_classes: int, hidden: int = HIDDEN, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    dims = [in_dim, hidden, hidden, hidden]
    params = {}
    for i in range(3):
        params[f"w{i}"] = jax.random.normal(ks[2 * i], (dims[i], dims[i + 1])) / np.sqrt(dims[i])
        params[f"b{i}"] = jnp.zeros(dims[i + 1])
        params[f"gamma{i}"] = jnp.ones(dims[i])
        params[f"beta{i}"] = jnp.zeros(dims[i])
    params["w_out"] = jax.random.normal(ks[6], (hidden, num_classes)) / np.sqrt(hidden)
    params["b_out"] = jnp.zeros(num_classes)
    return params


def mlp_apply(bundle_or_params, x: jax.Array, mu=None, sigma=None) -> jax.Array:
    """Forward. Accepts (params, mu, sigma) or an MLPB bundle."""
    if isinstance(bundle_or_params, MLPB):
        p, mu, sigma = bundle_or_params.params, bundle_or_params.mu, bundle_or_params.sigma
    else:
        p = bundle_or_params
    h = (x.astype(jnp.float32) - mu) / sigma  # dataset-stat normalization
    for i in range(3):
        h = p[f"gamma{i}"] * h + p[f"beta{i}"]          # BN affine (folded)
        h = h @ p[f"w{i}"] + p[f"b{i}"]                 # FC
        if True:
            h_pre = h
        h = jax.nn.relu(h)                              # ReLU
    return h @ p["w_out"] + p["b_out"]


def train_mlp(x: np.ndarray, y: np.ndarray, num_classes: int, *, steps=800, seed=0) -> MLPB:
    mu = x.astype(np.float32).mean(0)
    sigma = x.astype(np.float32).std(0) + 1e-3
    params = init_mlp(x.shape[1], num_classes, seed=seed)
    params = train_classifier(
        params,
        lambda p, xb: mlp_apply(p, xb, mu, sigma),
        x, y, steps=steps, seed=seed,
    )
    return MLPB(params=params, mu=mu, sigma=sigma, num_classes=num_classes)


# ---------------------------------------------------------------------------
# Pegasusification: dense teacher → fused LUT banks
# ---------------------------------------------------------------------------


def _activations(bundle: MLPB, x: np.ndarray) -> list[np.ndarray]:
    """Per-bank calibration inputs: raw x, then each FC's pre-activation."""
    p, mu, sigma = bundle.params, bundle.mu, bundle.sigma
    acts = [x.astype(np.float32)]
    h = (jnp.asarray(x, jnp.float32) - mu) / sigma
    for i in range(3):
        h = p[f"gamma{i}"] * h + p[f"beta{i}"]
        h = h @ p[f"w{i}"] + p[f"b{i}"]
        acts.append(np.asarray(h))
        h = jax.nn.relu(h)
    return acts  # [x, pre1, pre2, pre3]


def pegasusify_mlp(
    bundle: MLPB,
    x_calib: np.ndarray,
    *,
    group_size: int = 2,
    depth: int = 6,
    refine_steps: int = 100,
) -> list[PegasusLinear]:
    """Lower the trained MLP to 4 fused Pegasus banks (Fig. 5 ① result).

    Bank 0: idx on raw 8-bit stats; LUT = (norm·BN0 affine)(c) @ W0 + b0.
    Bank i: idx on pre-act i;       LUT = (BNi affine ∘ ReLU)(c) @ Wi + bi.
    Bank 3: classifier;             LUT = ReLU(c) @ W_out + b_out.
    """
    p, mu, sigma = bundle.params, bundle.mu, bundle.sigma
    acts = _activations(bundle, x_calib)
    layers = []

    def affine_fold(i, include_norm: bool):
        g = np.asarray(p[f"gamma{i}"], np.float32)
        b = np.asarray(p[f"beta{i}"], np.float32)
        if include_norm:
            scale = g / sigma
            shift = b - g * mu / sigma
        else:
            scale, shift = g, b

        def fn(c):  # c: [K, C, v] stacked centroids; slice per group
            k, _, v = c.shape
            s = scale.reshape(k, 1, v)
            t = shift.reshape(k, 1, v)
            return s * c + t

        return fn

    # bank 0: raw input → FC0 pre-activation
    layers.append(
        init_pegasus_linear(
            np.asarray(p["w0"]), np.asarray(p["b0"]), acts[0],
            group_size=group_size, depth=depth, lut_bits=None,
            act_fn=affine_fold(0, include_norm=True),
        )
    )
    # banks 1..2: pre-act i → pre-act i+1 (fold ReLU + BN affine)
    for i in (1, 2):
        aff = affine_fold(i, include_norm=False)
        layers.append(
            init_pegasus_linear(
                np.asarray(p[f"w{i}"]), np.asarray(p[f"b{i}"]), acts[i],
                group_size=group_size, depth=depth, lut_bits=None,
                act_fn=lambda c, aff=aff: aff(jnp.maximum(c, 0.0)),
            )
        )
    # classifier bank
    layers.append(
        init_pegasus_linear(
            np.asarray(p["w_out"]), np.asarray(p["b_out"]), acts[3],
            group_size=group_size, depth=depth, lut_bits=None,
            act_fn=lambda c: jnp.maximum(c, 0.0),
        )
    )

    if refine_steps:
        from repro.core.finetune import refine

        refined = []
        for i, layer in enumerate(layers):
            xb = jnp.asarray(acts[i])
            if i == 0:
                tgt = jnp.asarray(acts[1])
            elif i < 3:
                tgt = jnp.asarray(acts[i + 1])
            else:
                tgt = mlp_apply(bundle, jnp.asarray(x_calib))
            refined.append(refine(layer, xb, tgt, steps=refine_steps))
        layers = refined
    return layers


def pegasus_mlp_apply(
    layers: list[PegasusLinear], x: jax.Array, *,
    backend: str = "gather", path: str | None = None, jit: bool = False,
) -> jax.Array:
    """Run the fused bank stack via the execution engine (hard routing,
    deployment semantics). ``path`` is a deprecated alias for ``backend``.
    Eager by default — one-shot evaluation entry point; serving call sites
    (PegasusServer / build_plan) get the jitted path."""
    return plan_for(layers)(x, backend=path if path is not None else backend,
                            jit=jit)
