"""Shared training/eval utilities for the paper's six models + baselines."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule

__all__ = ["train_classifier", "macro_f1", "precision_recall", "xent"]


def xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()


def macro_f1(pred: np.ndarray, true: np.ndarray, n_classes: int) -> float:
    """Paper's metric: average F1 across classes (macro-accuracy)."""
    f1s = []
    for c in range(n_classes):
        tp = float(((pred == c) & (true == c)).sum())
        fp = float(((pred == c) & (true != c)).sum())
        fn = float(((pred != c) & (true == c)).sum())
        pr = tp / (tp + fp) if tp + fp else 0.0
        rc = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(2 * pr * rc / (pr + rc) if pr + rc else 0.0)
    return float(np.mean(f1s))


def precision_recall(pred: np.ndarray, true: np.ndarray, n_classes: int) -> tuple[float, float]:
    prs, rcs = [], []
    for c in range(n_classes):
        tp = float(((pred == c) & (true == c)).sum())
        fp = float(((pred == c) & (true != c)).sum())
        fn = float(((pred != c) & (true == c)).sum())
        prs.append(tp / (tp + fp) if tp + fp else 0.0)
        rcs.append(tp / (tp + fn) if tp + fn else 0.0)
    return float(np.mean(prs)), float(np.mean(rcs))


def train_classifier(
    params: Any,
    apply_fn: Callable[[Any, jax.Array], jax.Array],
    x_train: np.ndarray,
    y_train: np.ndarray,
    *,
    steps: int = 600,
    batch_size: int = 256,
    lr: float = 3e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
    loss_fn: Callable | None = None,
) -> Any:
    """Minimal AdamW training loop (CPU-friendly sizes)."""
    x_train = jnp.asarray(x_train)
    y_train = jnp.asarray(y_train)
    n = x_train.shape[0]
    sched = cosine_schedule(lr, warmup_steps=max(steps // 20, 1), total_steps=steps)
    state = adamw_init(params)
    lfn = loss_fn or (lambda p, xb, yb: xent(apply_fn(p, xb), yb))

    @jax.jit
    def step_fn(params, state, xb, yb):
        loss, grads = jax.value_and_grad(lfn)(params, xb, yb)
        params, state, _ = adamw_update(
            params, grads, state, lr=sched(state.step), weight_decay=weight_decay
        )
        return params, state, loss

    key = jax.random.PRNGKey(seed)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        ix = jax.random.randint(sub, (min(batch_size, n),), 0, n)
        params, state, _ = step_fn(params, state, x_train[ix], y_train[ix])
    return params


def evaluate(apply_fn, params, x, y, n_classes: int) -> dict:
    logits = np.asarray(apply_fn(params, jnp.asarray(x)))
    pred = logits.argmax(-1)
    pr, rc = precision_recall(pred, np.asarray(y), n_classes)
    return dict(f1=macro_f1(pred, np.asarray(y), n_classes), pr=pr, rc=rc)
