"""AutoEncoder (paper §6.3, §7.4): unsupervised anomaly detection on the
dataplane via MAE reconstruction error over (len, IPD) sequences.

Dense teacher: Emb-style input projection → FC encoder → FC decoder,
trained on BENIGN traffic only. Deployment form: every FC becomes a fused
Pegasus bank (Advanced Fusion applies — the paper lists AutoEncoder among
the models using it); the MAE and threshold compare are dataplane ALU ops.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, init_pegasus_linear
from repro.engine import plan_for
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule

__all__ = ["AutoEncoder", "train_autoencoder", "ae_apply", "reconstruction_error",
           "pegasusify_ae", "pegasus_ae_error", "auc_score"]

LATENT = 3
HIDDEN = 12


@dataclasses.dataclass
class AutoEncoder:
    params: dict
    in_dim: int


def init_ae(in_dim: int, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w_e1": jax.random.normal(ks[0], (in_dim, HIDDEN)) / np.sqrt(in_dim),
        "b_e1": jnp.zeros(HIDDEN),
        "w_e2": jax.random.normal(ks[1], (HIDDEN, LATENT)) / np.sqrt(HIDDEN),
        "b_e2": jnp.zeros(LATENT),
        "w_d1": jax.random.normal(ks[2], (LATENT, HIDDEN)) / np.sqrt(LATENT),
        "b_d1": jnp.zeros(HIDDEN),
        "w_d2": jax.random.normal(ks[3], (HIDDEN, in_dim)) / np.sqrt(HIDDEN),
        "b_d2": jnp.zeros(in_dim),
    }


def ae_apply(p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32) / 255.0
    h = jax.nn.relu(xf @ p["w_e1"] + p["b_e1"])
    z = jax.nn.relu(h @ p["w_e2"] + p["b_e2"])
    h = jax.nn.relu(z @ p["w_d1"] + p["b_d1"])
    return h @ p["w_d2"] + p["b_d2"]            # reconstruction in [0,1] units


def reconstruction_error(p: dict, x: jax.Array) -> jax.Array:
    """MAE per flow (the paper's anomaly score)."""
    recon = ae_apply(p, x)
    return jnp.abs(recon - x.astype(jnp.float32) / 255.0).mean(axis=-1)


def train_autoencoder(x_benign: np.ndarray, *, steps: int = 1200, seed: int = 0) -> AutoEncoder:
    in_dim = x_benign.shape[1]
    params = init_ae(in_dim, seed)
    x = jnp.asarray(x_benign)
    sched = cosine_schedule(3e-3, warmup_steps=30, total_steps=steps)
    state = adamw_init(params)

    @jax.jit
    def step_fn(params, state, xb):
        def loss(p):
            return jnp.abs(ae_apply(p, xb) - xb.astype(jnp.float32) / 255.0).mean()

        l, g = jax.value_and_grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, lr=sched(state.step), weight_decay=1e-4)
        return params, state, l

    key = jax.random.PRNGKey(seed)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        ix = jax.random.randint(sub, (256,), 0, x.shape[0])
        params, state, _ = step_fn(params, state, x[ix])
    return AutoEncoder(params=params, in_dim=in_dim)


# ---------------------------------------------------------------------------
# Pegasus deployment form
# ---------------------------------------------------------------------------


def pegasusify_ae(ae: AutoEncoder, x_calib: np.ndarray, *, depth: int = 8) -> list[PegasusLinear]:
    """Four fused banks (1-D groups: per-unit 2^8-entry tables, ReLU folded)."""
    p = ae.params
    xf = x_calib.astype(np.float32)
    acts = [xf]
    h = jnp.asarray(xf) / 255.0
    for w, b in [("w_e1", "b_e1"), ("w_e2", "b_e2"), ("w_d1", "b_d1")]:
        h = h @ p[w] + p[b]
        acts.append(np.asarray(h))
        h = jax.nn.relu(h)
    banks = [
        init_pegasus_linear(
            np.asarray(p["w_e1"], np.float32) / 255.0, np.asarray(p["b_e1"], np.float32),
            acts[0], group_size=1, depth=depth, lut_bits=None,
        )
    ]
    for i, (w, b) in enumerate([("w_e2", "b_e2"), ("w_d1", "b_d1"), ("w_d2", "b_d2")]):
        banks.append(
            init_pegasus_linear(
                np.asarray(p[w], np.float32), np.asarray(p[b], np.float32),
                acts[i + 1], group_size=1, depth=depth, lut_bits=None,
                act_fn=lambda c: jnp.maximum(c, 0.0),
            )
        )
    return banks


def pegasus_ae_error(
    banks: list[PegasusLinear], x: jax.Array, *, backend: str = "gather",
    jit: bool = False
) -> jax.Array:
    """Reconstruction MAE through the engine's bank-stack plan. Eager by
    default — one-shot evaluation entry point; serving call sites get the
    jitted path."""
    h = plan_for(banks)(x, backend=backend, jit=jit)
    return jnp.abs(h - x.astype(jnp.float32) / 255.0).mean(axis=-1)


def auc_score(scores: np.ndarray, labels: np.ndarray) -> float:
    """AUROC via the rank statistic (no sklearn)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
