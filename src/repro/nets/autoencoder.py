"""AutoEncoder (paper §6.3, §7.4): unsupervised anomaly detection on the
dataplane via reconstruction error over (len, IPD) sequence features.

Dense teacher: engineered window features → standardize on benign traffic →
FC encoder → FC decoder, trained on BENIGN flows only. Deployment form:
every FC becomes a fused Pegasus bank (Advanced Fusion applies — the paper
lists AutoEncoder among the models using it); the feature stats, the MAE and
the threshold compare are dataplane ALU ops, and the benign standardization
is folded into the first bank's weights so the switch sees raw 8-bit
features.

Why features + standardization (the seed's known-failing AUC): raw
(len, IPD) windows have per-dimension scales differing by >10x, so the MAE
score was dominated by high-variance packet-length dims and attacks that sit
*inside* the raw range (C&C beaconing: in-range lengths, unusual regularity)
scored at chance. :func:`anomaly_features` appends per-signal temporal stats
(mean/std/lag-1/lag-2 deltas — the periodicity fingerprint), and the score
is measured in benign z-space, where out-of-manifold inputs can't be
reconstructed (the banks' calibration-range clamping enforces this
structurally in the deployed form).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amm import PegasusLinear, init_pegasus_linear
from repro.engine import plan_for
from repro.train.optimizer import adamw_init, adamw_update, cosine_schedule

__all__ = ["AutoEncoder", "AEBanks", "anomaly_features", "train_autoencoder",
           "ae_apply", "reconstruction_error", "pegasusify_ae",
           "pegasus_ae_error", "auc_score"]

LATENT = 3
HIDDEN = 12
Z_CLIP = 6.0       # input saturation in benign σ units; mimics the deployed
# banks, whose trees clamp to the benign calibration range


@dataclasses.dataclass
class AutoEncoder:
    params: dict
    in_dim: int                 # anomaly_features output dim
    feat_mu: np.ndarray         # benign feature mean, [0, 1] units
    feat_sigma: np.ndarray      # benign feature std (floored), [0, 1] units


class AEBanks(list):
    """Pegasus deployment form: a plain bank list (the engine compiles it
    like any MLP stack — ``build_plan``/``plan_for`` accept it unchanged)
    carrying the benign standardization the anomaly score needs."""

    def __init__(self, banks, feat_mu: np.ndarray, feat_sigma: np.ndarray):
        super().__init__(banks)
        self.feat_mu = np.asarray(feat_mu, np.float32)
        self.feat_sigma = np.asarray(feat_sigma, np.float32)


def anomaly_features(x: jax.Array) -> jax.Array:
    """Flattened (len, IPD) window → window + temporal-stat features.

    ``x``: ``[..., W*2]`` interleaved ``(len_t, ipd_t)`` 8-bit values. Appends,
    per signal: mean, 2·std, mean |lag-1 Δ|, mean |lag-2 Δ| — all clipped to
    the same 0..255 PHV range (each is a running-sum/abs-diff ALU op on the
    switch). Lag-1 vs lag-2 separates periodic beaconing (large Δ1, tiny Δ2)
    from bursty-but-aperiodic benign traffic.
    """
    x = jnp.asarray(x, jnp.float32)
    lens, ipds = x[..., 0::2], x[..., 1::2]
    feats = [x]
    for s in (lens, ipds):
        feats += [
            s.mean(-1, keepdims=True),
            s.std(-1, keepdims=True) * 2.0,
            jnp.abs(jnp.diff(s, axis=-1)).mean(-1, keepdims=True),
            jnp.abs(s[..., 2:] - s[..., :-2]).mean(-1, keepdims=True),
        ]
    return jnp.clip(jnp.concatenate(feats, axis=-1), 0.0, 255.0)


def init_ae(in_dim: int, seed: int = 0) -> dict:
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "w_e1": jax.random.normal(ks[0], (in_dim, HIDDEN)) / np.sqrt(in_dim),
        "b_e1": jnp.zeros(HIDDEN),
        "w_e2": jax.random.normal(ks[1], (HIDDEN, LATENT)) / np.sqrt(HIDDEN),
        "b_e2": jnp.zeros(LATENT),
        "w_d1": jax.random.normal(ks[2], (LATENT, HIDDEN)) / np.sqrt(LATENT),
        "b_d1": jnp.zeros(HIDDEN),
        "w_d2": jax.random.normal(ks[3], (HIDDEN, in_dim)) / np.sqrt(HIDDEN),
        "b_d2": jnp.zeros(in_dim),
    }


def _z_apply(p: dict, z: jax.Array) -> jax.Array:
    """Encoder/decoder over standardized features; reconstruction in z units.
    Inputs saturate at ±Z_CLIP but the score compares against the UNCLIPPED
    z, so far-out-of-manifold inputs are unreconstructable by construction."""
    zc = jnp.clip(z, -Z_CLIP, Z_CLIP)
    h = jax.nn.relu(zc @ p["w_e1"] + p["b_e1"])
    lat = jax.nn.relu(h @ p["w_e2"] + p["b_e2"])
    h = jax.nn.relu(lat @ p["w_d1"] + p["b_d1"])
    return h @ p["w_d2"] + p["b_d2"]


def _standardize(ae_or_banks, x: jax.Array) -> jax.Array:
    feats = anomaly_features(x)
    mu = jnp.asarray(ae_or_banks.feat_mu)
    sigma = jnp.asarray(ae_or_banks.feat_sigma)
    return (feats / 255.0 - mu) / sigma


def ae_apply(ae: AutoEncoder, x: jax.Array) -> jax.Array:
    """Raw window → z-space reconstruction (dense teacher)."""
    return _z_apply(ae.params, _standardize(ae, x))


def reconstruction_error(ae: AutoEncoder, x: jax.Array) -> jax.Array:
    """MAE per flow in benign z-space (the anomaly score)."""
    z = _standardize(ae, x)
    return jnp.abs(_z_apply(ae.params, z) - z).mean(axis=-1)


def train_autoencoder(x_benign: np.ndarray, *, steps: int = 400, seed: int = 0) -> AutoEncoder:
    feats = np.asarray(anomaly_features(x_benign))
    feat_mu = feats.mean(0) / 255.0
    feat_sigma = np.maximum(feats.std(0) / 255.0, 1e-3)
    in_dim = feats.shape[1]
    params = init_ae(in_dim, seed)
    z = jnp.asarray((feats / 255.0 - feat_mu) / feat_sigma)
    sched = cosine_schedule(3e-3, warmup_steps=30, total_steps=steps)
    state = adamw_init(params)

    @jax.jit
    def step_fn(params, state, zb):
        def loss(p):
            return jnp.abs(_z_apply(p, zb) - zb).mean()

        l, g = jax.value_and_grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, lr=sched(state.step), weight_decay=1e-4)
        return params, state, l

    key = jax.random.PRNGKey(seed)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        ix = jax.random.randint(sub, (256,), 0, z.shape[0])
        params, state, _ = step_fn(params, state, z[ix])
    return AutoEncoder(params=params, in_dim=in_dim,
                       feat_mu=feat_mu, feat_sigma=feat_sigma)


# ---------------------------------------------------------------------------
# Pegasus deployment form
# ---------------------------------------------------------------------------


def pegasusify_ae(ae: AutoEncoder, x_calib: np.ndarray, *, depth: int = 8) -> AEBanks:
    """Four fused banks (1-D groups: per-unit 2^depth-entry tables, ReLU
    folded). The first bank consumes RAW 0..255 features — the /255,
    mean-shift and 1/σ of the benign standardization are folded into its
    weights — so the switch pipeline never materializes floats."""
    p = ae.params
    mu, sigma = ae.feat_mu, ae.feat_sigma
    feats = np.asarray(anomaly_features(x_calib), np.float32)
    # pre-activations along the z path, for per-bank calibration
    acts = [feats]
    h = jnp.asarray((feats / 255.0 - mu) / sigma)
    for w, b in [("w_e1", "b_e1"), ("w_e2", "b_e2"), ("w_d1", "b_d1")]:
        h = h @ p[w] + p[b]
        acts.append(np.asarray(h))
        h = jax.nn.relu(h)
    w_e1 = np.asarray(p["w_e1"], np.float32)
    w1 = w_e1 / (255.0 * sigma[:, None])
    b1 = np.asarray(p["b_e1"], np.float32) - (mu / sigma) @ w_e1
    banks = [
        init_pegasus_linear(w1, b1, acts[0], group_size=1, depth=depth,
                            lut_bits=None)
    ]
    for i, (w, b) in enumerate([("w_e2", "b_e2"), ("w_d1", "b_d1"), ("w_d2", "b_d2")]):
        banks.append(
            init_pegasus_linear(
                np.asarray(p[w], np.float32), np.asarray(p[b], np.float32),
                acts[i + 1], group_size=1, depth=depth, lut_bits=None,
                act_fn=lambda c: jnp.maximum(c, 0.0),
            )
        )
    return AEBanks(banks, mu, sigma)


def pegasus_ae_error(
    banks: AEBanks, x: jax.Array, *, backend: str = "gather",
    jit: bool = False
) -> jax.Array:
    """Reconstruction MAE through the engine's bank-stack plan, in benign
    z-space. Eager by default — one-shot evaluation entry point; serving
    call sites get the jitted path (``build_plan``/``MultiModelServer``)."""
    feats = anomaly_features(x)
    zhat = plan_for(banks)(feats, backend=backend, jit=jit)
    z = (feats / 255.0 - jnp.asarray(banks.feat_mu)) / jnp.asarray(banks.feat_sigma)
    return jnp.abs(zhat - z).mean(axis=-1)


def auc_score(scores: np.ndarray, labels: np.ndarray) -> float:
    """AUROC via the rank statistic (no sklearn)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos, n_neg = int(pos.sum()), int((~pos).sum())
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
