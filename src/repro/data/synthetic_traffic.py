"""Synthetic traffic-classification datasets standing in for PeerRush /
CICIOT / ISCXVPN (paper §7.1).

The real captures are not redistributable and unavailable offline, so we
generate class-conditional flow models with the same *structure* the paper's
models exploit:

  * per-class Markov chains over packet-length states (temporal dependence —
    what RNN/CNN capture, and what pure statistical features miss),
  * per-class log-normal inter-packet-delay (IPD) mixtures,
  * per-class payload byte distributions (for CNN-L's raw-byte input),
  * heavy overlap between classes so the task is non-trivial and model
    capacity/feature-scale differences show up in macro-F1 — mirroring the
    paper's ordering (binary < fixed-point < bigger inputs).

Datasets (name → #classes): ``peerrush`` → 3, ``ciciot`` → 3, ``iscxvpn`` → 7.
Feature views per flow window (W = 8 packets):
  * ``stats``  : 16 × 8-bit  (max/min/mean-ish packet len + IPD summaries) — MLP/N3IC/Leo input (128 bits)
  * ``seq``    : W × 2 × 8-bit  (len, IPD per packet) — RNN/BoS/CNN-B/M input (128 bits)
  * ``bytes``  : W × 60 × 8-bit raw payload bytes — CNN-L input (3840 bits)
All features are 8-bit unsigned integers exactly as a switch PHV carries them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TrafficDataset", "make_dataset", "DATASETS", "anomaly_testset"]

DATASETS = {"peerrush": 3, "ciciot": 3, "iscxvpn": 7}
WINDOW = 8
N_BYTES = 60


@dataclasses.dataclass
class TrafficDataset:
    name: str
    num_classes: int
    # train/val/test splits, each dict with "stats", "seq", "bytes", "label"
    train: dict
    val: dict
    test: dict


def _class_params(rng: np.random.Generator, c: int, n_classes: int, hardness: float):
    """Markov chain + IPD + byte-histogram parameters for one class."""
    n_states = 6
    # transition matrix: shared base + class-specific structure
    base = rng.dirichlet(np.ones(n_states) * 2.0, size=n_states)
    ident = np.roll(np.eye(n_states), c % n_states, axis=1)
    trans = (1 - hardness) * ident + hardness * base
    trans /= trans.sum(1, keepdims=True)
    # state → packet-length distribution (mean, std), spread across [40, 250]
    means = np.linspace(40, 250, n_states) + rng.normal(0, 10, n_states) + 6 * c
    stds = rng.uniform(5, 25, n_states)
    # IPD log-normal params per class
    ipd_mu = rng.uniform(1.0, 3.5) + 0.25 * c
    ipd_sigma = rng.uniform(0.3, 0.9)
    # payload byte profile: Dirichlet over 256 values, few class-salient bytes
    byte_profile = rng.dirichlet(np.ones(256) * 0.08)
    return trans, means, stds, ipd_mu, ipd_sigma, byte_profile


def _gen_flows(rng, params, n_flows: int, cls: int):
    trans, means, stds, ipd_mu, ipd_sigma, byte_profile = params
    n_states = trans.shape[0]
    lens = np.zeros((n_flows, WINDOW), np.float32)
    ipds = np.zeros((n_flows, WINDOW), np.float32)
    payload = rng.choice(256, size=(n_flows, WINDOW, N_BYTES), p=byte_profile)
    state = rng.integers(0, n_states, n_flows)
    for t in range(WINDOW):
        lens[:, t] = np.clip(rng.normal(means[state], stds[state]), 0, 255)
        ipds[:, t] = np.clip(rng.lognormal(ipd_mu, ipd_sigma, n_flows), 0, 255)
        # advance Markov state
        u = rng.random(n_flows)
        cdf = np.cumsum(trans[state], axis=1)
        state = (u[:, None] < cdf).argmax(axis=1)
    seq = np.stack([lens, ipds], axis=-1).astype(np.uint8)          # [F, W, 2]

    stats = np.stack(
        [
            lens.max(1), lens.min(1), lens.mean(1), lens.std(1),
            ipds.max(1), ipds.min(1), ipds.mean(1), ipds.std(1),
            np.abs(np.diff(lens, axis=1)).mean(1), np.abs(np.diff(ipds, axis=1)).mean(1),
            (lens > 128).sum(1) * 16.0, (ipds > 32).sum(1) * 16.0,
            lens[:, 0], lens[:, -1], ipds[:, 0], ipds[:, -1],
        ],
        axis=1,
    )
    stats = np.clip(stats, 0, 255).astype(np.uint8)                 # [F, 16]
    labels = np.full(n_flows, cls, np.int32)
    return stats, seq, payload.astype(np.uint8), labels


def make_dataset(
    name: str,
    flows_per_class: int = 1500,
    seed: int | None = None,
    hardness: float | None = None,
) -> TrafficDataset:
    """Build one synthetic dataset with the paper's 75/10/15 split."""
    n_classes = DATASETS[name]
    seed = {"peerrush": 101, "ciciot": 202, "iscxvpn": 303}[name] if seed is None else seed
    # ISCXVPN (VPN-encrypted, 7 classes) is the hardest task in the paper
    hardness = {"peerrush": 0.45, "ciciot": 0.55, "iscxvpn": 0.62}[name] if hardness is None else hardness
    rng = np.random.default_rng(seed)

    all_stats, all_seq, all_bytes, all_y = [], [], [], []
    for c in range(n_classes):
        params = _class_params(rng, c, n_classes, hardness)
        s, q, b, y = _gen_flows(rng, params, flows_per_class, c)
        all_stats.append(s); all_seq.append(q); all_bytes.append(b); all_y.append(y)

    stats = np.concatenate(all_stats)
    seq = np.concatenate(all_seq)
    payload = np.concatenate(all_bytes)
    y = np.concatenate(all_y)
    perm = rng.permutation(len(y))
    stats, seq, payload, y = stats[perm], seq[perm], payload[perm], y[perm]

    n = len(y)
    n_tr, n_va = int(0.75 * n), int(0.10 * n)

    def split(lo, hi):
        return dict(stats=stats[lo:hi], seq=seq[lo:hi], bytes=payload[lo:hi], label=y[lo:hi])

    return TrafficDataset(
        name=name,
        num_classes=n_classes,
        train=split(0, n_tr),
        val=split(n_tr, n_tr + n_va),
        test=split(n_tr + n_va, n),
    )


def anomaly_testset(
    base: TrafficDataset, kind: str = "malware", ratio: float = 0.25, seed: int = 7
) -> dict:
    """Benign test flows + injected attack flows at 1:4 (paper §7.4).

    ``malware``: shifted Markov/byte profiles (C&C-like beaconing);
    ``dos``: SSDP-reflection-like — near-constant large packets, tiny IPD.
    Returns dict with the three feature views and binary ``label``
    (1 = attack).
    """
    rng = np.random.default_rng(seed)
    benign = base.test
    n_attack = int(len(benign["label"]) * ratio)

    if kind == "dos":
        lens = np.clip(rng.normal(240, 4, (n_attack, WINDOW)), 0, 255)
        ipds = np.clip(rng.lognormal(0.0, 0.1, (n_attack, WINDOW)), 0, 255)
        byte_profile = np.zeros(256); byte_profile[77] = 0.7
        byte_profile += 0.3 / 256
        byte_profile /= byte_profile.sum()
    else:  # malware: beaconing with unusual periodicity + rare bytes
        lens = np.clip(rng.normal(90, 6, (n_attack, WINDOW)) + 40 * (np.arange(WINDOW) % 2), 0, 255)
        ipds = np.clip(rng.lognormal(4.5, 0.15, (n_attack, WINDOW)), 0, 255)
        byte_profile = rng.dirichlet(np.ones(256) * 0.01)

    payload = rng.choice(256, size=(n_attack, WINDOW, N_BYTES), p=byte_profile).astype(np.uint8)
    seq = np.stack([lens, ipds], axis=-1).astype(np.uint8)
    stats = np.stack(
        [
            lens.max(1), lens.min(1), lens.mean(1), lens.std(1),
            ipds.max(1), ipds.min(1), ipds.mean(1), ipds.std(1),
            np.abs(np.diff(lens, axis=1)).mean(1), np.abs(np.diff(ipds, axis=1)).mean(1),
            (lens > 128).sum(1) * 16.0, (ipds > 32).sum(1) * 16.0,
            lens[:, 0], lens[:, -1], ipds[:, 0], ipds[:, -1],
        ],
        axis=1,
    )
    stats = np.clip(stats, 0, 255).astype(np.uint8)

    out = dict(
        stats=np.concatenate([benign["stats"], stats]),
        seq=np.concatenate([benign["seq"], seq]),
        bytes=np.concatenate([benign["bytes"], payload]),
        label=np.concatenate(
            [np.zeros(len(benign["label"]), np.int32), np.ones(n_attack, np.int32)]
        ),
    )
    perm = rng.permutation(len(out["label"]))
    return {k: v[perm] for k, v in out.items()}
