"""From-scratch optimizers (no optax): AdamW + cosine schedule + clipping.

Written as pure pytree transforms so optimizer state shards exactly like the
parameters under pjit (ZeRO-style: m/v inherit the param sharding — see
launch/train.py's shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule", "clip_by_global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array           # scalar int32
    m: Any                    # pytree like params
    v: Any                    # pytree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(
    params: Any,
    grads: Any,
    state: AdamWState,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    max_grad_norm: float | None = 1.0,
) -> tuple[Any, AdamWState, jax.Array]:
    """One AdamW step. Returns (new_params, new_state, grad_norm)."""
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        _, gnorm = clip_by_global_norm(grads, jnp.inf)

    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr_at(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr_at
