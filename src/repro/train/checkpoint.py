"""Sharded checkpointing with atomic commit, keep-last-k GC, and elastic
restore (resharding onto a different mesh).

Layout:  <dir>/step_<N>/
           manifest.json            tree structure, shapes, dtypes, step
           <flat-key>.npy           one file per leaf (host-gathered)
         <dir>/step_<N>.COMMITTED   commit marker (atomic rename)

Fault model: a crash mid-save leaves no COMMITTED marker → restore picks the
last committed step; a crash mid-training resumes from the last checkpoint
(checkpoint-restart is the TPU SPMD fault-tolerance primitive — see
DESIGN.md §5). Save can run on a background thread (``async_save``) so the
training loop only blocks on the previous save's completion.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "async_save", "AsyncCheckpointer"]

_SEP = "::"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Synchronous checkpoint save with atomic commit marker."""
    stepdir = os.path.join(ckpt_dir, f"step_{step}")
    tmpdir = stepdir + ".tmp"
    if os.path.exists(tmpdir):
        shutil.rmtree(tmpdir)
    os.makedirs(tmpdir, exist_ok=True)

    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}}
    for key, arr in flat.items():
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmpdir, fname), arr)
        manifest["keys"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmpdir, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(stepdir):                      # same-step re-save
        shutil.rmtree(stepdir)
    os.replace(tmpdir, stepdir)                      # atomic on POSIX
    open(stepdir + ".COMMITTED", "w").close()

    _gc(ckpt_dir, keep)
    return stepdir


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{s}.COMMITTED"))
        except OSError:
            pass


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.endswith(".COMMITTED"):
            out.append(int(name[len("step_"):-len(".COMMITTED")]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = latest_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, target: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``target``.

    ``shardings`` (optional pytree of NamedSharding) enables ELASTIC
    restore: arrays are device_put onto the new mesh regardless of the mesh
    they were saved from (host-gathered .npy files are mesh-agnostic).
    """
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    stepdir = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(stepdir, "manifest.json")) as f:
        manifest = json.load(f)

    flat_t = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    shard_leaves = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    for i, (kp, leaf) in enumerate(flat_t[0]):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        info = manifest["keys"][key]
        arr = np.load(os.path.join(stepdir, info["file"]))
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves), step


class AsyncCheckpointer:
    """Background-thread checkpointing: training blocks only on the PREVIOUS
    save (bounded staleness of one)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any):
        self.wait()
        # materialize to host BEFORE backgrounding (device buffers may mutate)
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, host_tree),
            kwargs=dict(keep=self.keep), daemon=True,
        )
        self._thread.start()


def async_save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> AsyncCheckpointer:
    ck = AsyncCheckpointer(ckpt_dir, keep)
    ck.save(step, tree)
    return ck
